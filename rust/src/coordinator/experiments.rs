//! One driver per paper table/figure (DESIGN.md §3 maps them).
//!
//! Every driver returns a [`Table`] (or rendered text) so the CLI, the
//! benches and EXPERIMENTS.md generation share identical numbers.

use anyhow::Result;

use crate::coordinator::report::{f, Table};
use crate::coordinator::sweep::{base_latency, peak_throughput, LoadSweep, SweepPoint};
use crate::lattice::symmetry;
use crate::metrics::{distance_distribution, formulas, max_throughput_bound};
use crate::sim::{RoutePolicy, SimConfig, SimConfig as SC, TrafficPattern};
use crate::topology;

/// Table 1: distance properties of the cubic crystals vs mixed-radix tori.
pub fn table1(a_values: &[i64]) -> Table {
    let mut t = Table::new(
        "Table 1 — distance properties of cubic crystal lattice graphs",
        &["topology", "a", "nodes", "diameter", "model", "avg dist", "formula"],
    );
    for &a in a_values {
        let rows: Vec<(String, crate::lattice::LatticeGraph, i64, f64)> = vec![
            ("PC(a)".into(), topology::pc(a), formulas::diameter_pc(a), formulas::avg_distance_pc(a)),
            ("T(2a,a,a)".into(), topology::torus(&[2 * a, a, a]), formulas::diameter_torus(&[2 * a, a, a]), formulas::avg_distance_torus(&[2 * a, a, a])),
            ("FCC(a)".into(), topology::fcc(a), formulas::diameter_fcc(a), formulas::avg_distance_fcc(a)),
            ("T(2a,2a,a)".into(), topology::torus(&[2 * a, 2 * a, a]), formulas::diameter_torus(&[2 * a, 2 * a, a]), formulas::avg_distance_torus(&[2 * a, 2 * a, a])),
            ("BCC(a)".into(), topology::bcc(a), formulas::diameter_bcc(a), formulas::avg_distance_bcc(a)),
        ];
        for (name, g, dia_model, avg_model) in rows {
            let s = distance_distribution(&g);
            assert_eq!(s.diameter as i64, dia_model, "{name} a={a} diameter model");
            t.row(vec![
                name,
                a.to_string(),
                g.order().to_string(),
                s.diameter.to_string(),
                dia_model.to_string(),
                f(s.avg_distance, 4),
                f(avg_model, 4),
            ]);
        }
    }
    t
}

/// §3.4 closed-form check "up to 40,000 nodes": exact BFS vs formulas for
/// every crystal size until `max_nodes`.
pub fn formulas_check(max_nodes: usize) -> Table {
    let mut t = Table::new(
        "§3.4 closed forms vs exact BFS",
        &["topology", "a", "nodes", "bfs avg", "formula", "abs err"],
    );
    let fams: [(&str, fn(i64) -> crate::lattice::LatticeGraph, fn(i64) -> f64, fn(i64) -> usize); 3] = [
        ("PC", topology::pc as fn(i64) -> _, formulas::avg_distance_pc as fn(i64) -> f64, (|a| (a * a * a) as usize) as fn(i64) -> usize),
        ("FCC", topology::fcc, formulas::avg_distance_fcc, |a| (2 * a * a * a) as usize),
        ("BCC", topology::bcc, formulas::avg_distance_bcc, |a| (4 * a * a * a) as usize),
    ];
    for (name, ctor, formula, order_of) in fams {
        let mut a = 2i64;
        while order_of(a) <= max_nodes {
            let g = ctor(a);
            let s = distance_distribution(&g);
            let fo = formula(a);
            let err = (s.avg_distance - fo).abs();
            assert!(err < 1e-9, "{name}({a}) formula mismatch: {} vs {fo}", s.avg_distance);
            t.row(vec![
                format!("{name}(a)"),
                a.to_string(),
                g.order().to_string(),
                f(s.avg_distance, 6),
                f(fo, 6),
                format!("{err:.1e}"),
            ]);
            a += 1;
        }
    }
    t
}

/// §3.4 analytic throughput bounds and headline gains.
pub fn bounds(a_values: &[i64]) -> Table {
    let mut t = Table::new(
        "§3.4 throughput bounds (phits/cycle/node)",
        &["a", "FCC", "T(2a,a,a)", "FCC gain", "BCC", "T(2a,2a,a)", "BCC gain"],
    );
    for &a in a_values {
        let fcc = max_throughput_bound(&topology::fcc(a)).phits_per_cycle_node;
        let t1 = max_throughput_bound(&topology::torus(&[2 * a, a, a])).phits_per_cycle_node;
        let bcc = max_throughput_bound(&topology::bcc(a)).phits_per_cycle_node;
        let t2 = max_throughput_bound(&topology::torus(&[2 * a, 2 * a, a])).phits_per_cycle_node;
        t.row(vec![
            a.to_string(),
            f(fcc, 4),
            f(t1, 4),
            format!("{:+.0}%", (fcc / t1 - 1.0) * 100.0),
            f(bcc, 4),
            f(t2, 4),
            format!("{:+.0}%", (bcc / t2 - 1.0) * 100.0),
        ]);
    }
    t
}

/// Table 2: the lifted/hybrid lattice graphs.
pub fn table2(a_values: &[i64]) -> Table {
    let mut t = Table::new(
        "Table 2 — distance properties of lifted/hybrid lattice graphs",
        &["topology", "a", "dim", "nodes", "diameter", "paper dia", "avg dist", "paper avg"],
    );
    for &a in a_values {
        let rows: Vec<(usize, crate::lattice::LatticeGraph)> = vec![
            (0, topology::hybrid_t_rtt(a)),
            (1, topology::fcc4d(a)),
            (2, topology::bcc4d(a)),
            (3, topology::lip(a)),
            (4, topology::hybrid_pc_bcc(a)),
            (5, topology::hybrid_pc_fcc(a)),
            (6, topology::hybrid_bcc_fcc(a)),
        ];
        for (i, g) in rows {
            let row = &formulas::TABLE2[i];
            if g.order() > 600_000 {
                continue; // keep the driver snappy at large a
            }
            let s = distance_distribution(&g);
            t.row(vec![
                row.name.to_string(),
                a.to_string(),
                g.dim().to_string(),
                g.order().to_string(),
                s.diameter.to_string(),
                f(row.diameter_coeff * a as f64, 1),
                f(s.avg_distance, 4),
                f(row.avg_coeff * a as f64, 4),
            ]);
        }
    }
    t
}

/// Figure 4: the lift/projection tree.
pub fn tree(max_dim: usize) -> String {
    let tree = topology::tree::build_tree(max_dim);
    let mut out = String::new();
    topology::tree::render(&tree, 0, &mut out);
    out
}

/// Theorem 20: the finite search for symmetric BCC lifts.
pub fn thm20(a_values: &[i64]) -> Table {
    let mut t = Table::new(
        "Theorem 20 — symmetric lifts of BCC(a) (finite search, t = 1)",
        &["a", "lifts examined", "symmetric found"],
    );
    for &a in a_values {
        let examined = (2 * a) * (2 * a) * a;
        let found = symmetry::symmetric_bcc_lifts(a);
        assert!(found.is_empty(), "Theorem 20 violated at a={a}");
        t.row(vec![a.to_string(), examined.to_string(), found.len().to_string()]);
    }
    t
}

/// Figures 1–2 / Example 10: cycle structure joining projection copies.
pub fn cycles() -> String {
    use crate::math::IMat;
    let g = crate::lattice::LatticeGraph::new(IMat::from_rows(&[
        &[4, 0, 0],
        &[0, 4, 2],
        &[0, 0, 4],
    ]));
    let p = g.project();
    let cycle = g.cycle_through(0);
    let mut out = String::new();
    out.push_str("Example 10: G(M), M = [[4,0,0],[0,4,2],[0,0,4]] (64 nodes)\n");
    out.push_str(&format!(
        "projection: G(B) = T(4,4); side a = {}; copies = {}\n",
        p.side, p.side
    ));
    out.push_str(&format!(
        "cycle <e_3>: length {} ({} parallel cycles, {} vertices per copy)\n",
        p.cycle_len, p.num_cycles, p.intersections_per_copy
    ));
    out.push_str("cycle through node 0 (labels):\n");
    for idx in &cycle {
        out.push_str(&format!("  {:?}\n", g.label_of(*idx)));
    }
    // RTT(4) perpendicular cycles (Figure 1).
    let rtt = topology::rtt(4);
    out.push_str(&format!(
        "\nRTT(4): ord(e_1) = {}, ord(e_2) = {} (two perpendicular length-8 cycles)\n",
        rtt.generator_order(0),
        rtt.generator_order(1)
    ));
    out
}

/// Figure 3: the three crystals at a glance.
pub fn crystals(a: i64) -> Table {
    let mut t = Table::new(
        "Figure 3 — the cubic crystal graphs",
        &["crystal", "nodes", "degree", "diameter", "avg dist", "symmetric", "projection"],
    );
    let rows: Vec<(&str, crate::lattice::LatticeGraph, &str)> = vec![
        ("PC(a)", topology::pc(a), "T(a,a)"),
        ("FCC(a)", topology::fcc(a), "RTT(a)"),
        ("BCC(a)", topology::bcc(a), "T(2a,2a)"),
    ];
    for (name, g, proj) in rows {
        let s = distance_distribution(&g);
        t.row(vec![
            name.to_string(),
            g.order().to_string(),
            g.degree().to_string(),
            s.diameter.to_string(),
            f(s.avg_distance, 4),
            g.is_symmetric().to_string(),
            proj.to_string(),
        ]);
    }
    t
}

/// Appendix Table 4: the 48 signed permutations of length 3 with orders.
pub fn appendix() -> Table {
    let mut t = Table::new(
        "Appendix Table 4 — signed permutations of 3 elements",
        &["perm", "signs", "order"],
    );
    for p in symmetry::signed_permutations(3) {
        t.row(vec![
            format!("{:?}", p.perm),
            format!("{:?}", p.signs),
            p.order().to_string(),
        ]);
    }
    t
}

/// §6.1 partitioning: each lattice machine hands out copies of its
/// projection as user partitions; crystals hand out *symmetric* ones.
pub fn partition_report() -> Table {
    let mut t = Table::new(
        "§6.1 — network partitioning into projection copies",
        &["machine", "nodes", "partitions", "partition graph", "part. nodes", "part. symmetric", "verified"],
    );
    let cases: Vec<(&str, crate::lattice::LatticeGraph, &str)> = vec![
        ("PC(4)", topology::pc(4), "T(4,4)"),
        ("FCC(4)", topology::fcc(4), "RTT(4)"),
        ("BCC(4)", topology::bcc(4), "T(8,8)"),
        ("4D-FCC(2)", topology::fcc4d(2), "FCC(2)"),
        ("4D-BCC(2)", topology::bcc4d(2), "PC(4)"),
        ("T(8,8,4)", topology::torus(&[8, 8, 4]), "T(8,8)"),
    ];
    for (name, g, proj_name) in cases {
        let parts = g.partitions();
        let proj = g.projection_graph();
        t.row(vec![
            name.to_string(),
            g.order().to_string(),
            parts.len().to_string(),
            proj_name.to_string(),
            proj.order().to_string(),
            proj.is_symmetric().to_string(),
            g.partitions_are_projection_copies().to_string(),
        ]);
    }
    t
}

/// §3.4 resource-usage experiment: per-dimension link utilization at
/// saturation. The paper's claim: in `T(2a,a,a)` the long dimension
/// saturates while the two short dimensions idle at ~50%; edge-symmetric
/// crystals load every dimension evenly.
pub fn link_usage(a: i64, sim: SimConfig) -> Table {
    let mut t = Table::new(
        "§3.4 — per-dimension link utilization at saturation (uniform)",
        &["topology", "accepted", "util dim0", "util dim1", "util dim2", "max/min"],
    );
    let cases: Vec<(String, crate::lattice::LatticeGraph)> = vec![
        (format!("T({},{a},{a})", 2 * a), topology::torus(&[2 * a, a, a])),
        (format!("T({},{},{a})", 2 * a, 2 * a), topology::torus(&[2 * a, 2 * a, a])),
        (format!("FCC({a})"), topology::fcc(a)),
        (format!("BCC({a})"), topology::bcc(a)),
    ];
    for (name, g) in cases {
        let s = crate::sim::Simulator::new(g, TrafficPattern::Uniform, sim.clone());
        let r = s.run(1.0);
        let u = &r.link_utilization;
        let maxu = u.iter().cloned().fold(0.0, f64::max);
        let minu = u.iter().cloned().fold(f64::INFINITY, f64::min);
        t.row(vec![
            name,
            f(r.accepted_load, 4),
            f(u[0], 3),
            f(u[1], 3),
            f(u[2], 3),
            f(maxu / minu, 2),
        ]);
    }
    t
}

/// Router-model ablation: how each Table 3 design choice moves peak
/// throughput and latency (uniform traffic, FCC(4) + T(8,8,4) testbeds).
pub fn ablation(base: SimConfig) -> Table {
    let mut t = Table::new(
        "router-model ablation (uniform, peak over loads 0.4..1.0)",
        &["variant", "FCC(4) peak", "FCC(4) lat@0.4", "T(8,8,4) peak", "T(8,8,4) lat@0.4"],
    );
    let variants: Vec<(&str, SimConfig)> = vec![
        ("baseline (2 VCs)", base.clone()),
        ("1 VC", SimConfig { num_vcs: 1, ..base.clone() }),
        ("3 VCs (Table 3)", SimConfig { num_vcs: 3, ..base.clone() }),
        ("no bubble", SimConfig { bubble: false, ..base.clone() }),
        ("no transit priority", SimConfig { transit_priority: false, ..base.clone() }),
        ("2-packet queues", SimConfig { queue_packets: 2, ..base.clone() }),
        ("8-phit packets", SimConfig { packet_size: 8, ..base.clone() }),
    ];
    // Both testbed bundles are built once and shared across the variant
    // grid — every variant only changes config knobs, never the topology.
    let arts: Vec<_> = [topology::fcc(4), topology::torus(&[8, 8, 4])]
        .into_iter()
        .map(|g| crate::sim::TopologyArtifacts::build(g, base.threads))
        .collect();
    for (name, cfg) in variants {
        let mut cells = vec![name.to_string()];
        for art in &arts {
            let sim =
                crate::sim::Simulator::with_artifacts(art.clone(), TrafficPattern::Uniform, cfg.clone());
            let peak = [0.4, 0.6, 0.8, 1.0]
                .iter()
                .map(|&l| sim.run(l).accepted_load)
                .fold(0.0, f64::max);
            let lat = sim.run(0.4).avg_latency;
            cells.push(f(peak, 4));
            cells.push(f(lat, 1));
        }
        t.row(cells);
    }
    t
}

/// Collective-workload comparison: closed-loop completion time of every
/// [`WorkloadKind`](crate::workload::WorkloadKind) on the crystals vs
/// matched-order mixed-radix tori (PC/RTT/FCC/BCC vs `T(a,a,a)`,
/// `T(2a,a)`, `T(2a,a,a)`, `T(2a,2a,a)`), swept over application payload
/// sizes (`sizes`, in phits — multi-packet messages serialize at the
/// source NIC, so the sweep exposes exactly the serialization effects a
/// single-packet model flattens) and over route-selection policies
/// (`policies` — the per-hop balancing axis; empty = DOR only). Each side
/// carries a per-link utilization `spread` column (max/mean over the
/// run's directed links — the closed-loop balance instrumentation). Jobs
/// fan out over the shared worker pool; each network's
/// [`TopologyArtifacts`](crate::sim::TopologyArtifacts) bundle is built
/// once and shared by its per-policy simulators.
pub fn collectives(
    a: i64,
    iters: usize,
    seeds: usize,
    sizes: &[u32],
    policies: &[RoutePolicy],
    sim: SimConfig,
) -> Table {
    use crate::sim::{Simulator, TopologyArtifacts};
    use crate::workload::{
        generate, par_map, CompletionPoint, WorkloadKind, WorkloadParams, WorkloadRunner,
    };

    let default_sizes = [crate::workload::DEFAULT_MSG_PHITS];
    let sizes: &[u32] = if sizes.is_empty() { &default_sizes } else { sizes };
    let default_policies = [RoutePolicy::Dor];
    let policies: &[RoutePolicy] = if policies.is_empty() { &default_policies } else { policies };
    let pairs: Vec<[(String, crate::lattice::LatticeGraph); 2]> = vec![
        [
            (format!("PC({a})"), topology::pc(a)),
            (format!("T({a},{a},{a})"), topology::torus(&[a, a, a])),
        ],
        [
            (format!("RTT({a})"), topology::rtt(a)),
            (format!("T({},{a})", 2 * a), topology::torus(&[2 * a, a])),
        ],
        [
            (format!("FCC({a})"), topology::fcc(a)),
            (format!("T({},{a},{a})", 2 * a), topology::torus(&[2 * a, a, a])),
        ],
        [
            (format!("BCC({a})"), topology::bcc(a)),
            (format!("T({},{},{a})", 2 * a, 2 * a), topology::torus(&[2 * a, 2 * a, a])),
        ],
    ];
    // One artifacts bundle per network; one simulator per (network,
    // policy) sharing it.
    let build = |(name, g): (String, crate::lattice::LatticeGraph)| -> (String, Vec<Simulator>) {
        let art = TopologyArtifacts::build(g, sim.threads);
        let sims = policies
            .iter()
            .map(|&p| {
                let cfg = SimConfig { route_policy: p, ..sim.clone() };
                Simulator::with_artifacts(art.clone(), TrafficPattern::Uniform, cfg)
            })
            .collect();
        (name, sims)
    };
    let sims: Vec<[(String, Vec<Simulator>); 2]> =
        pairs.into_iter().map(|[l, t]| [build(l), build(t)]).collect();
    // Inner seed fan-out stays serial: the outer (pair × kind × size ×
    // policy × side) jobs already fill the pool.
    let runner = WorkloadRunner { sim: sim.clone(), seeds, workers: 1, max_cycles: None };
    let kinds = WorkloadKind::ALL;
    let mut jobs: Vec<(usize, usize, usize, usize, usize)> = Vec::new();
    for pi in 0..sims.len() {
        for ki in 0..kinds.len() {
            for si in 0..sizes.len() {
                for qi in 0..policies.len() {
                    for side in 0..2 {
                        jobs.push((pi, ki, si, qi, side));
                    }
                }
            }
        }
    }
    let points = par_map(jobs.len(), 0, |j| {
        let (pi, ki, si, qi, side) = jobs[j];
        let (name, nets) = &sims[pi][side];
        let params = WorkloadParams { iters, payload_phits: sizes[si], ..Default::default() };
        let wl = generate(kinds[ki], nets[qi].graph(), &params);
        runner.run_with(&nets[qi], name, &wl)
    });

    let mut t = Table::new(
        &format!("collective workloads — completion cycles vs payload and route policy, crystals vs matched tori (a = {a})"),
        &["workload", "payload", "policy", "messages", "lattice", "cycles", "eff bw", "spread", "p99.9", "torus", "cycles", "eff bw", "spread", "p99.9", "torus/lattice"],
    );
    let mark = |p: &CompletionPoint| {
        if p.drained {
            f(p.completion_cycles, 0)
        } else {
            format!(">{:.0}", p.completion_cycles)
        }
    };
    for pi in 0..sims.len() {
        for ki in 0..kinds.len() {
            for si in 0..sizes.len() {
                for qi in 0..policies.len() {
                    let base =
                        (((pi * kinds.len() + ki) * sizes.len() + si) * policies.len() + qi) * 2;
                    let l = &points[base];
                    let r = &points[base + 1];
                    t.row(vec![
                        kinds[ki].name().to_string(),
                        sizes[si].to_string(),
                        policies[qi].name().to_string(),
                        l.messages.to_string(),
                        l.topology.clone(),
                        mark(l),
                        f(l.effective_bandwidth, 4),
                        f(l.link_util_spread, 2),
                        f(l.p999_latency, 1),
                        r.topology.clone(),
                        mark(r),
                        f(r.effective_bandwidth, 4),
                        f(r.link_util_spread, 2),
                        f(r.p999_latency, 1),
                        format!("{:.2}x", r.completion_cycles / l.completion_cycles.max(1.0)),
                    ]);
                }
            }
        }
    }
    t
}

/// Route-selection policy comparison (the per-hop balancing story): open-
/// loop accepted throughput, latency and per-link utilization spread at
/// high offered load, per (policy × VC count), on the edge-asymmetric
/// mixed-radix torus `T(2a,a,a)` vs the matched crystal `FCC(a)`. Fixed
/// DOR ordering concentrates load on physically distinct intermediate
/// links under global patterns; `AdaptiveMin` is measured by how much
/// accepted throughput it buys back (and how far it pulls the spread
/// down). The VC column separates unprotected single-VC adaptivity —
/// which can genuinely deadlock at saturation — from the escape-VC
/// configurations (`vcs >= 2`), whose `esc share` column reports how much
/// hop traffic drained through the deadlock-free DOR channel.
pub fn route_policies(
    a: i64,
    loads: &[f64],
    policies: &[RoutePolicy],
    patterns: &[TrafficPattern],
    vcs: &[usize],
    sim: SimConfig,
) -> Table {
    use crate::workload::par_map;

    let default_vcs = [sim.num_vcs];
    let vcs: &[usize] = if vcs.is_empty() { &default_vcs } else { vcs };
    let mut t = Table::new(
        &format!(
            "route-selection policies — accepted load, link balance and escape-VC usage (a = {a})"
        ),
        &[
            "topology", "traffic", "policy", "vcs", "offered", "accepted", "avg lat", "p99",
            "p99.9", "util spread", "esc share",
        ],
    );
    let cases: Vec<(String, crate::lattice::LatticeGraph)> = vec![
        (format!("T({},{a},{a})", 2 * a), topology::torus(&[2 * a, a, a])),
        (format!("FCC({a})"), topology::fcc(a)),
    ];
    for (name, g) in cases {
        // One artifacts bundle per network; one simulator per (pattern,
        // policy, VC count) sharing it; the (sim × load) grid fans out
        // over the worker pool (order-preserving, like the collectives
        // driver).
        let art = crate::sim::TopologyArtifacts::build(g, sim.threads);
        let mut sims = Vec::new();
        for &pattern in patterns {
            for &policy in policies {
                for &nv in vcs {
                    let cfg = SimConfig { route_policy: policy, num_vcs: nv, ..sim.clone() };
                    let s = crate::sim::Simulator::with_artifacts(art.clone(), pattern, cfg);
                    sims.push((pattern, policy, nv, s));
                }
            }
        }
        let results = par_map(sims.len() * loads.len(), 0, |j| {
            let (si, li) = (j / loads.len(), j % loads.len());
            sims[si].3.run(loads[li])
        });
        for (si, (pattern, policy, nv, s)) in sims.iter().enumerate() {
            for (li, &load) in loads.iter().enumerate() {
                let r = &results[si * loads.len() + li];
                t.row(vec![
                    name.clone(),
                    pattern.name().to_string(),
                    policy.name().to_string(),
                    nv.to_string(),
                    f(load, 2),
                    f(r.accepted_load, 4),
                    f(r.avg_latency, 1),
                    f(r.p99_latency, 1),
                    f(r.p999_latency, 1),
                    f(r.link_util_spread, 2),
                    if s.escape_active() { f(r.escape_share(), 3) } else { "-".into() },
                ]);
            }
        }
    }
    t
}

/// Degraded-mode resilience sweep: accepted throughput and latency under
/// rising random link-fault rates, crystals vs their matched mixed-radix
/// tori (`FCC(a)` vs `T(2a,a,a)`, `BCC(a)` vs `T(2a,2a,a)`). Each (rate,
/// seed) cell builds a fresh simulator — the fault draw derives from the
/// run seed at construction — and runs uniform traffic at a fixed
/// moderate offered load; rows average over seeds. The `surviving`
/// column is the live fraction of nodes in the largest connected
/// component of the faulted graph (the BFS oracle in `metrics::bfs`), so
/// the table separates capacity lost to disconnection from capacity lost
/// to detour congestion.
pub fn degradation(a: i64, rates: &[f64], seeds: usize, sim: SimConfig) -> Table {
    use crate::metrics::faulted_components;
    use crate::workload::par_map;

    let load = 0.3;
    let seeds = seeds.max(1);
    let mut t = Table::new(
        &format!(
            "degradation under link faults — uniform at offered {load}, {seeds} seed(s) per rate (a = {a})"
        ),
        &[
            "topology",
            "rate",
            "dead links",
            "surviving",
            "accepted",
            "avg lat",
            "delivered",
            "src dropped",
        ],
    );
    let cases: Vec<(String, crate::lattice::LatticeGraph)> = vec![
        (format!("FCC({a})"), topology::fcc(a)),
        (format!("T({},{a},{a})", 2 * a), topology::torus(&[2 * a, a, a])),
        (format!("BCC({a})"), topology::bcc(a)),
        (format!("T({},{},{a})", 2 * a, 2 * a), topology::torus(&[2 * a, 2 * a, a])),
    ];
    for (name, g) in cases {
        // One artifacts bundle per network; one simulator per (rate,
        // seed) sharing it — the fault set is config-derived and stays
        // per-simulator, so the grid only re-draws faults, never the
        // tables. The (rate × seed) grid fans out over the worker pool.
        let art = crate::sim::TopologyArtifacts::build(g, sim.threads);
        let mut sims = Vec::new();
        for &rate in rates {
            for s in 0..seeds {
                let cfg = SimConfig {
                    link_fault_rate: rate,
                    seed: sim.seed.wrapping_add(s as u64 * 0x9e37_79b9_7f4a_7c15),
                    ..sim.clone()
                };
                sims.push(crate::sim::Simulator::with_artifacts(
                    art.clone(),
                    TrafficPattern::Uniform,
                    cfg,
                ));
            }
        }
        let results = par_map(sims.len(), 0, |j| sims[j].run(load));
        for (ri, &rate) in rates.iter().enumerate() {
            let (mut dead, mut surv, mut acc, mut lat, mut del, mut dropped) =
                (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for s in 0..seeds {
                let i = ri * seeds + s;
                let r = &results[i];
                acc += r.accepted_load;
                lat += r.avg_latency;
                del += r.delivered_packets as f64;
                dropped += r.source_dropped as f64;
                match sims[i].faults() {
                    Some(fs) => {
                        dead += fs.dead_links() as f64;
                        let comp =
                            faulted_components(sims[i].graph(), fs.node_dead_mask(), |u, ax, sg| {
                                fs.is_edge_dead(u, ax, sg)
                            });
                        let mut counts: Vec<usize> = Vec::new();
                        for &c in &comp {
                            if c == u32::MAX {
                                continue;
                            }
                            if c as usize >= counts.len() {
                                counts.resize(c as usize + 1, 0);
                            }
                            counts[c as usize] += 1;
                        }
                        let largest = counts.iter().copied().max().unwrap_or(0);
                        surv += largest as f64 / sims[i].graph().order() as f64;
                    }
                    None => surv += 1.0,
                }
            }
            let k = seeds as f64;
            t.row(vec![
                name.clone(),
                f(rate, 3),
                f(dead / k, 1),
                f(surv / k, 3),
                f(acc / k, 4),
                f(lat / k, 1),
                f(del / k, 0),
                f(dropped / k, 0),
            ]);
        }
    }
    t
}

/// A figure specification: two networks compared under the 4 traffics.
pub struct FigSpec {
    pub id: &'static str,
    /// (display name, topology spec) — mixed-radix torus baseline.
    pub torus: (&'static str, &'static str),
    /// The lattice (crystal lift) competitor.
    pub lattice: (&'static str, &'static str),
}

/// Figure 5/7 pair: T(16,8,8,8) vs 4D-FCC(8) (8192 nodes).
pub fn fig5_spec(full: bool) -> FigSpec {
    if full {
        FigSpec { id: "fig5", torus: ("T(16,8,8,8)", "torus:16x8x8x8"), lattice: ("4D-FCC(8)", "4d-fcc:8") }
    } else {
        // Scaled default: same shapes at half radix (512 nodes each).
        FigSpec { id: "fig5(scaled)", torus: ("T(8,4,4,4)", "torus:8x4x4x4"), lattice: ("4D-FCC(4)", "4d-fcc:4") }
    }
}

/// Figure 6/8 pair: T(8,8,8,4) vs 4D-BCC(4) (2048 nodes).
pub fn fig6_spec(full: bool) -> FigSpec {
    if full {
        FigSpec { id: "fig6", torus: ("T(8,8,8,4)", "torus:8x8x8x4"), lattice: ("4D-BCC(4)", "4d-bcc:4") }
    } else {
        FigSpec { id: "fig6(scaled)", torus: ("T(4,4,4,2)", "torus:4x4x4x2"), lattice: ("4D-BCC(2)", "4d-bcc:2") }
    }
}

/// Result of simulating one figure: per-network per-pattern sweep curves.
pub struct FigResult {
    pub id: String,
    /// (network name, pattern, points)
    pub curves: Vec<(String, TrafficPattern, Vec<SweepPoint>)>,
}

/// Run a figure's sweeps.
pub fn run_figure(
    spec: &FigSpec,
    patterns: &[TrafficPattern],
    loads: &[f64],
    seeds: usize,
    sim: SimConfig,
) -> Result<FigResult> {
    let mut curves = Vec::new();
    for (name, tspec) in [spec.torus, spec.lattice] {
        let g = topology::catalog::parse(tspec)?.graph;
        let art = crate::sim::TopologyArtifacts::build(g, sim.threads);
        for &pattern in patterns {
            let simr = crate::sim::Simulator::with_artifacts(art.clone(), pattern, sim.clone());
            let sweep = LoadSweep { loads: loads.to_vec(), seeds, sim: sim.clone(), workers: 0 };
            let points = sweep.run_with(&simr);
            curves.push((name.to_string(), pattern, points));
        }
    }
    Ok(FigResult { id: spec.id.to_string(), curves })
}

/// Throughput-peak summary table (Figures 5–6).
pub fn throughput_table(fig: &FigResult) -> Table {
    let mut t = Table::new(
        &format!("{} — peak accepted throughput (phits/cycle/node)", fig.id),
        &["network", "traffic", "peak", "latency@low"],
    );
    for (name, pattern, points) in &fig.curves {
        t.row(vec![
            name.clone(),
            pattern.name().to_string(),
            f(peak_throughput(points), 4),
            f(base_latency(points), 1),
        ]);
    }
    t
}

/// Per-pattern gain summary: lattice peak / torus peak − 1.
pub fn gain_table(fig: &FigResult) -> Table {
    let mut t = Table::new(
        &format!("{} — lattice gain over torus", fig.id),
        &["traffic", "torus peak", "lattice peak", "gain"],
    );
    for pattern in TrafficPattern::ALL {
        let find = |i: usize| {
            fig.curves
                .iter()
                .filter(|(_, p, _)| *p == pattern)
                .nth(i)
                .map(|(_, _, pts)| peak_throughput(pts))
        };
        if let (Some(torus), Some(lattice)) = (find(0), find(1)) {
            t.row(vec![
                pattern.name().to_string(),
                f(torus, 4),
                f(lattice, 4),
                format!("{:+.0}%", (lattice / torus - 1.0) * 100.0),
            ]);
        }
    }
    t
}

/// Full curve table (Figures 5–8 series: load vs accepted vs latency).
pub fn curve_table(fig: &FigResult) -> Table {
    let mut t = Table::new(
        &format!("{} — sweep curves", fig.id),
        &["network", "traffic", "offered", "accepted", "avg latency", "p99"],
    );
    for (name, pattern, points) in &fig.curves {
        for p in points {
            t.row(vec![
                name.clone(),
                pattern.name().to_string(),
                f(p.offered_load, 2),
                f(p.accepted_load, 4),
                f(p.avg_latency, 1),
                f(p.p99_latency, 1),
            ]);
        }
    }
    t
}

/// Default sweep parameters for the figure drivers.
pub fn default_loads() -> Vec<f64> {
    (1..=10).map(|i| i as f64 / 10.0).collect()
}

/// Scaled-vs-full simulation parameters.
///
/// The figure drivers reproduce the paper's Table 3 router, so they pin
/// `num_vcs = 3` rather than inheriting the crate default of 2 (the
/// escape-protocol configuration). Note the CLI replaces this whole
/// config with the file's `[sim]` section only when that file sets
/// `sim.measure_cycles` (see `cmd_experiment` in `main.rs`).
pub fn fig_sim_config(full: bool) -> (SimConfig, usize) {
    let table3 = SC { num_vcs: 3, ..SC::default() };
    if full {
        (table3, 5) // paper: 10k cycles, >= 5 sims per point
    } else {
        (SC { warmup_cycles: 1_000, measure_cycles: 4_000, ..table3 }, 3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_smoke() {
        let t = table1(&[2, 4]);
        assert_eq!(t.rows.len(), 10);
        assert!(t.render().contains("BCC"));
    }

    #[test]
    fn formulas_check_small() {
        let t = formulas_check(600);
        assert!(t.rows.len() >= 6);
    }

    #[test]
    fn bounds_headline() {
        let t = bounds(&[16]);
        let rendered = t.render();
        // finite-size value approaches the asymptotic +71% from above
        assert!(rendered.contains("+71%") || rendered.contains("+72%"), "{rendered}");
        assert!(rendered.contains("+37%") || rendered.contains("+36%"), "{rendered}");
    }

    #[test]
    fn table2_smoke() {
        let t = table2(&[2]);
        assert_eq!(t.rows.len(), 7);
    }

    #[test]
    fn thm20_smoke() {
        let t = thm20(&[1, 2]);
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn cycles_text() {
        let s = cycles();
        assert!(s.contains("length 8"));
    }

    #[test]
    fn appendix_counts() {
        let t = appendix();
        assert_eq!(t.rows.len(), 48);
    }

    #[test]
    fn ablation_runs_and_baseline_wins_reasonably() {
        let cfg = SimConfig { warmup_cycles: 200, measure_cycles: 800, ..SimConfig::default() };
        let t = ablation(cfg);
        assert_eq!(t.rows.len(), 7);
        // 1 VC must not beat the 2-VC baseline on the twisted network.
        let base: f64 = t.rows[0][1].parse().unwrap();
        let one_vc: f64 = t.rows[1][1].parse().unwrap();
        assert!(one_vc <= base * 1.1, "1 VC {one_vc} vs baseline {base}");
    }

    #[test]
    fn partition_report_verified() {
        let t = partition_report();
        assert_eq!(t.rows.len(), 6);
        for row in &t.rows {
            assert_eq!(row[6], "true", "{row:?}");
        }
    }

    #[test]
    fn link_usage_shape() {
        // Edge-asymmetric T(2a,a,a) loads its long dimension ~2x the short
        // ones; edge-symmetric FCC/BCC stay within ~15% across dimensions.
        let sim = SimConfig { warmup_cycles: 400, measure_cycles: 2500, ..SimConfig::default() };
        let t = link_usage(4, sim);
        let ratio = |row: usize| -> f64 { t.rows[row][5].parse().unwrap() };
        assert!(ratio(0) > 1.5, "T(2a,a,a) max/min = {}", ratio(0));
        assert!(ratio(2) < 1.2, "FCC max/min = {}", ratio(2));
        assert!(ratio(3) < 1.2, "BCC max/min = {}", ratio(3));
    }

    #[test]
    fn collectives_smoke() {
        let cfg = SimConfig { warmup_cycles: 0, measure_cycles: 0, ..SimConfig::default() };
        let t = collectives(2, 2, 1, &[16], &[RoutePolicy::Dor], cfg);
        assert_eq!(t.rows.len(), 4 * 6, "4 pairs x 6 workloads x 1 size x 1 policy");
        for row in &t.rows {
            assert_eq!(row[2], "dor");
            assert!(!row[5].starts_with('>'), "lattice side must drain: {row:?}");
            assert!(!row[10].starts_with('>'), "torus side must drain: {row:?}");
            // Closed-loop balance columns: traffic moved, so max/mean >= 1.
            for col in [7, 12] {
                let spread: f64 = row[col].parse().unwrap();
                assert!(spread >= 1.0, "spread below 1: {row:?}");
            }
            // Tail-latency columns: positive whenever packets were delivered.
            for col in [8, 13] {
                let p999: f64 = row[col].parse().unwrap();
                assert!(p999 > 0.0, "p99.9 not positive: {row:?}");
            }
        }
        // PC(a) and T(a,a,a) are the same graph: completion within noise.
        let pc_ratio: f64 = t.rows[0][14].trim_end_matches('x').parse().unwrap();
        assert!(pc_ratio > 0.5 && pc_ratio < 2.0, "PC self-pair ratio {pc_ratio}");
    }

    #[test]
    fn collectives_payload_sweep_monotone() {
        // Two payload sizes per cell; bigger payloads serialize longer, so
        // every (pair, kind) completion must grow with the payload.
        let cfg = SimConfig { warmup_cycles: 0, measure_cycles: 0, ..SimConfig::default() };
        let t = collectives(2, 1, 1, &[16, 128], &[RoutePolicy::Dor], cfg);
        assert_eq!(t.rows.len(), 4 * 6 * 2, "4 pairs x 6 workloads x 2 sizes");
        let cycles = |row: &Vec<String>, col: usize| -> f64 {
            row[col].trim_start_matches('>').parse().unwrap()
        };
        for pair in t.rows.chunks(2) {
            let (small, big) = (&pair[0], &pair[1]);
            assert_eq!(small[0], big[0], "rows must pair by workload");
            assert_eq!(small[1], "16");
            assert_eq!(big[1], "128");
            for col in [5, 10] {
                assert!(
                    cycles(big, col) >= cycles(small, col),
                    "{} should not complete faster at 128 phits: {small:?} vs {big:?}",
                    small[0]
                );
            }
        }
    }

    #[test]
    fn collectives_policy_sweep_has_policy_rows() {
        // Every workload appears once per policy, all drained, and the
        // policy column carries the sweep (closed-loop runs on tiny
        // networks — correctness of the plumbing, not a benchmark).
        let cfg = SimConfig { warmup_cycles: 0, measure_cycles: 0, ..SimConfig::default() };
        let policies = [RoutePolicy::Dor, RoutePolicy::AdaptiveMin];
        let t = collectives(2, 1, 1, &[16], &policies, cfg);
        assert_eq!(t.rows.len(), 4 * 6 * 2, "4 pairs x 6 workloads x 2 policies");
        for pair in t.rows.chunks(2) {
            assert_eq!(pair[0][0], pair[1][0], "rows must pair by workload");
            assert_eq!(pair[0][2], "dor");
            assert_eq!(pair[1][2], "adaptive");
            for row in pair {
                assert!(!row[5].starts_with('>'), "must drain: {row:?}");
                assert!(!row[10].starts_with('>'), "must drain: {row:?}");
            }
        }
    }

    #[test]
    fn route_policies_smoke() {
        let cfg = SimConfig { warmup_cycles: 100, measure_cycles: 400, ..SimConfig::default() };
        let t = route_policies(
            2,
            &[0.3],
            &[RoutePolicy::Dor, RoutePolicy::AdaptiveMin],
            &[TrafficPattern::Uniform],
            &[1, 2],
            cfg,
        );
        assert_eq!(t.rows.len(), 2 * 2 * 2, "2 networks x 1 pattern x 2 policies x 2 VCs x 1 load");
        for row in &t.rows {
            let accepted: f64 = row[5].parse().unwrap();
            assert!(accepted > 0.0, "{row:?}");
            // The HDR tail columns must be ordered: p99 <= p99.9.
            let p99: f64 = row[7].parse().unwrap();
            let p999: f64 = row[8].parse().unwrap();
            assert!(p99 <= p999, "p99 above p99.9: {row:?}");
            let spread: f64 = row[9].parse().unwrap();
            assert!(spread >= 1.0, "max/mean spread below 1: {row:?}");
            // The escape-share column is live exactly when the escape
            // protocol is (adaptive policy with at least 2 VCs).
            if row[2] == "adaptive" && row[3] == "2" {
                let esc: f64 = row[10].parse().unwrap();
                assert!((0.0..=1.0).contains(&esc), "{row:?}");
            } else {
                assert_eq!(row[10], "-", "{row:?}");
            }
        }
    }

    #[test]
    fn degradation_smoke() {
        let cfg = SimConfig { warmup_cycles: 100, measure_cycles: 400, ..SimConfig::default() };
        let t = degradation(2, &[0.0, 0.2], 2, cfg);
        assert_eq!(t.rows.len(), 4 * 2, "4 networks x 2 rates");
        for pair in t.rows.chunks(2) {
            let (clean, faulty) = (&pair[0], &pair[1]);
            assert_eq!(clean[1], "0.000");
            // Rate 0 is the pristine engine: no dead hardware, whole
            // graph surviving.
            assert_eq!(clean[2], "0.0", "{clean:?}");
            assert_eq!(clean[3], "1.000", "{clean:?}");
            let dead: f64 = faulty[2].parse().unwrap();
            assert!(dead > 0.0, "rate 0.2 should kill some links: {faulty:?}");
            let surv: f64 = faulty[3].parse().unwrap();
            assert!(surv > 0.0 && surv <= 1.0, "{faulty:?}");
            // The degraded network still moves traffic between the
            // oracle-reachable pairs the admission gate allows.
            let clean_acc: f64 = clean[4].parse().unwrap();
            let faulty_acc: f64 = faulty[4].parse().unwrap();
            assert!(clean_acc > 0.0, "{clean:?}");
            assert!(faulty_acc > 0.0, "{faulty:?}");
        }
    }

    #[test]
    fn fig6_scaled_runs() {
        let spec = fig6_spec(false);
        let sim = SimConfig { warmup_cycles: 100, measure_cycles: 400, ..SimConfig::default() };
        let fig = run_figure(&spec, &[TrafficPattern::Uniform], &[0.2], 1, sim).unwrap();
        assert_eq!(fig.curves.len(), 2);
        let t = gain_table(&fig);
        assert_eq!(t.rows.len(), 1);
    }
}
