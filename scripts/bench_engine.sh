#!/usr/bin/env sh
# Regenerate the committed engine perf baseline (BENCH_engine.json at the
# repository root) from the engine_scaling bench. The measurement budget
# is pinned so trajectory points stay comparable across regenerations;
# override with BENCH_BUDGET_MS=<ms> for quicker smoke runs.
#
# The baseline includes the `open@0.9+trace` telemetry cases (JSONL
# lifecycle trace streaming to a scratch file): compare them against the
# matching `open@0.9` cases to read the trace-on overhead, and the
# `open@0.9` trajectory itself to bound the cost of the always-on stall
# counters (telemetry off).
#
# Every case carries serial/parallel twins (`.../t1` vs `.../t4`, the
# `SimConfig::threads` knob): the t4/t1 node-cycles/s ratio is the
# parallel-engine speedup. Read it off the busy cases (`open@0.9`, the
# T(32,32,32) stencil — the ≥2× target case); the `chain` twins bound
# the barrier overhead on serial-dependency workloads instead. CI's
# bench-smoke schema gate requires both twins for every case.
#
# The imbalance twins added with the balanced shard planner:
# `T(16,16,16)/hotspot-imbalance` (TrafficPattern::HotSpot — one
# saturated destination; its t4/t1 ratio measures per-cycle work-balanced
# sharding, ≥2× target vs the static-shard engine) and
# `T(16,16,16)/near-idle` (open@0.01; its t4 twin must track t1 thanks to
# the `serial_cutoff` fast path — barriers skipped on near-empty cycles).
# The schema gate also requires both regimes to be present.
#
# The `table_build` cases added with the topology plane track routing-
# table construction up to T(64,64,64): `serial-hier/t1` is the legacy
# serial hierarchical walk (boxed table, then compaction),
# `dispatch/t1`/`dispatch/t4` build the compact store directly from the
# closed-form dispatch routers. Throughput is nodes/s — read the
# dispatch/t4 vs serial-hier/t1 ratio at T(64,64,64) for the headline
# build speedup (≥5× target) — and each record's `extra` field carries
# `route_bytes_per_node` for the store-size trajectory. The schema gate
# requires all three variants per table_build topology.
#
# Usage: scripts/bench_engine.sh [output-path]
set -eu
cd "$(dirname "$0")/.."
out="${1:-BENCH_engine.json}"
# Cargo runs harness=false bench binaries with CWD at the *package* root
# (rust/), so hand the binary an absolute path or the records would land
# in rust/$out instead of the committed repo-root baseline.
case "$out" in
    /*) abs="$out" ;;
    *) abs="$(pwd)/$out" ;;
esac
BENCH_BUDGET_MS="${BENCH_BUDGET_MS:-300}" \
    cargo bench --bench engine_scaling -- --json "$abs"
echo "baseline written to $out"
